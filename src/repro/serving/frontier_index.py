"""Precomputed workload-family -> Pareto-frontier index for serving.

A campaign answers "what is the frontier for THESE workloads" offline; the
serving layer answers "what should I buy for THIS workload" online.  The
``FrontierIndex`` is the artifact between the two: built once from a
completed campaign (or its checkpoint), it maps each evaluated workload
family to its exact offline frontier, so a selection query on a known
family is a lookup — no sweep, no device, and the answer is *identical* to
the offline campaign pick by construction.

A workload family is keyed by its HxA-census feature vector — the same six
``costmodel.WL_COLS`` scalars (flops, hbm_bytes, collective_bytes,
wire_bytes, base_chips, state_gb_per_device) the fused sweep packs per
workload — so "same family" means "the cost model cannot tell them apart".
Lookup is O(log n): families are sorted by a 1-D projection of their
normalized log-features, a query binary-searches the projection
(``np.searchsorted``) and scans a constant-size window around the
insertion point with the full distance.  An exact hit (relative tolerance
``match_rtol``) always lands inside the window because equal vectors have
equal projections; for novel workloads ``nearest`` returns the closest
family in the window plus its distance, which the engine uses only as a
hint — novel answers are recomputed, never served from a neighbor.

Like checkpoints and fabric worker configs, the index stamps
``costmodel.SIM_MODEL_VERSION`` and refuses to load across a mismatch: an
index built under an old cost model would serve answers no current
campaign could reproduce.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel, dse
from repro.dse_campaign import store
from repro.dse_campaign.frontier import (candidate_from_dict,
                                         candidate_to_dict)
from repro.dse_campaign.runner import (Campaign, workload_from_dict,
                                       workload_to_dict)

INDEX_SCHEMA_VERSION = 1

# entries scanned around the searchsorted insertion point; exact matches
# need only the equal-projection run, the margin covers nearest-neighbor
# lookups whose true neighbor projects slightly off
LOOKUP_WINDOW = 8


def family_key(wl: dse.Workload) -> np.ndarray:
    """The workload's family feature vector — ``costmodel.WL_COLS`` order,
    float64.  One definition shared by index build and query so the two
    cannot disagree on what a family is."""
    return np.asarray(
        [wl.base_analysis["flops"], wl.base_analysis["hbm_bytes"],
         wl.base_analysis["collective_bytes"], wl.base_analysis["wire_bytes"],
         wl.base_chips, wl.state_gb_per_device], np.float64)


@dataclasses.dataclass(frozen=True)
class IndexEntry:
    """One workload family: its key vector, the workload it came from, and
    the family's exact offline frontier (canonical-order arrays)."""

    arch: str
    shape: str
    family: np.ndarray                     # family_key vector
    workload: dse.Workload
    candidates: Tuple[dse.Candidate, ...]  # frontier members
    energy_j: np.ndarray
    latency_s: np.ndarray
    indices: np.ndarray                    # global space indices
    feasible_count: int

    def frontier(self) -> dse.ParetoFrontier:
        """The stored frontier in ``dse.ParetoFrontier`` form."""
        return dse.ParetoFrontier(
            workload=self.workload, candidates=tuple(self.candidates),
            energy_j=self.energy_j.copy(), latency_s=self.latency_s.copy(),
            indices=self.indices.copy(),
            feasible_count=self.feasible_count)


class FrontierIndex:
    """Versioned family -> frontier map with O(log n) lookup.

    Build with ``from_campaign`` / ``from_checkpoint``, persist with
    ``save`` / ``load``.  The index also carries the campaign's space,
    constraint, evaluator and ``SimConfig`` dicts, so a ``SelectionEngine``
    can reconstruct the exact evaluation setup for novel-workload
    mini-campaigns without a side channel.
    """

    def __init__(self, entries: Sequence[IndexEntry], space_dict: Dict,
                 constraint_dict: Dict, sim_dict: Dict, evaluator: str):
        self.entries = list(entries)
        self.space_dict = dict(space_dict)
        self.constraint_dict = dict(constraint_dict)
        self.sim_dict = dict(sim_dict)
        self.evaluator = evaluator
        self._build_lookup()

    # -- lookup structure ---------------------------------------------------

    def _build_lookup(self) -> None:
        n = len(self.entries)
        feats = np.log1p(np.abs(np.stack(
            [e.family for e in self.entries]))) if n else np.zeros((0, 6))
        lo = feats.min(axis=0) if n else np.zeros(6)
        span = (feats.max(axis=0) - lo) if n else np.ones(6)
        span = np.where(span > 0, span, 1.0)
        self._feat_lo, self._feat_span = lo, span
        self._feats = (feats - lo) / span          # [n, 6] in [0, 1]
        proj = self._feats.sum(axis=1)
        self._order = np.argsort(proj, kind="stable")
        self._proj = proj[self._order]

    def _normalize(self, key: np.ndarray) -> np.ndarray:
        return (np.log1p(np.abs(key)) - self._feat_lo) / self._feat_span

    def _window(self, key: np.ndarray) -> np.ndarray:
        """Entry positions (into ``self.entries``) worth a full-distance
        check for ``key`` — the sorted-projection window."""
        if not self.entries:
            return np.empty(0, np.int64)
        q = self._normalize(key).sum()
        pos = int(np.searchsorted(self._proj, q))
        lo = max(0, pos - LOOKUP_WINDOW)
        hi = min(len(self._order), pos + LOOKUP_WINDOW)
        return self._order[lo:hi]

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def keys(self) -> List[Tuple[str, str]]:
        """(arch, shape) of every indexed family."""
        return [(e.arch, e.shape) for e in self.entries]

    def lookup(self, wl: dse.Workload, match_rtol: float = 1e-9
               ) -> Optional[IndexEntry]:
        """The entry whose family vector matches ``wl`` elementwise within
        ``match_rtol`` (and zero absolute tolerance — a family with a zero
        component only matches an exact zero), or ``None``.  A JSON
        round-trip preserves float64 exactly, so workloads that built the
        index always hit."""
        key = family_key(wl)
        for i in self._window(key):
            e = self.entries[i]
            if np.allclose(e.family, key, rtol=match_rtol, atol=0.0):
                return e
        return None

    def nearest(self, wl: dse.Workload) -> Tuple[Optional[IndexEntry], float]:
        """(closest-family entry, Euclidean distance in normalized log
        feature space) within the lookup window; ``(None, inf)`` on an
        empty index.  A distance of 0.0 is an exact family hit."""
        key = family_key(wl)
        win = self._window(key)
        if not win.size:
            return None, float("inf")
        q = self._normalize(key)
        d = np.linalg.norm(self._feats[win] - q, axis=1)
        best = int(np.argmin(d))
        return self.entries[int(win[best])], float(d[best])

    # -- build --------------------------------------------------------------

    @classmethod
    def from_campaign(cls, campaign: Campaign) -> "FrontierIndex":
        """Build the index from a COMPLETE campaign — a partial sweep would
        bake half-space frontiers into served answers, so it is refused."""
        if campaign.next_tile < campaign.space.n_tiles():
            raise ValueError(
                f"campaign is incomplete ({campaign.next_tile}/"
                f"{campaign.space.n_tiles()} tiles): an index built now "
                "would serve partial-space frontiers")
        entries = []
        for wl in campaign.workloads:
            fr = campaign.frontiers[(wl.arch, wl.shape)]
            front = fr.as_pareto_frontier(wl)
            entries.append(IndexEntry(
                arch=wl.arch, shape=wl.shape, family=family_key(wl),
                workload=wl, candidates=tuple(front.candidates),
                energy_j=np.asarray(front.energy_j, np.float64),
                latency_s=np.asarray(front.latency_s, np.float64),
                indices=np.asarray(front.indices, np.int64),
                feasible_count=int(front.feasible_count)))
        return cls(entries, campaign.space.to_dict(),
                   dataclasses.asdict(campaign.constraint),
                   dataclasses.asdict(campaign.sim), campaign.evaluator)

    @classmethod
    def from_checkpoint(cls, path: str) -> "FrontierIndex":
        """Build from a campaign checkpoint file.  Goes through
        ``Campaign.from_checkpoint``, so the checkpoint's
        ``SIM_MODEL_VERSION`` gate (and its upgrade error message) applies
        before any frontier is indexed."""
        return cls.from_campaign(Campaign.from_checkpoint(path))

    # -- persistence --------------------------------------------------------

    def to_dict(self) -> Dict:
        return {
            "index_schema_version": INDEX_SCHEMA_VERSION,
            "sim_model_version": costmodel.SIM_MODEL_VERSION,
            "space": self.space_dict,
            "constraint": self.constraint_dict,
            "sim": self.sim_dict,
            "evaluator": self.evaluator,
            "entries": [{
                "arch": e.arch, "shape": e.shape,
                "family": e.family.tolist(),
                "workload": workload_to_dict(e.workload),
                "candidates": [candidate_to_dict(c) for c in e.candidates],
                "energy_j": e.energy_j.tolist(),
                "latency_s": e.latency_s.tolist(),
                "indices": e.indices.tolist(),
                "feasible_count": e.feasible_count,
            } for e in self.entries],
        }

    def save(self, path: str) -> str:
        """Persist atomically (tmp + fsync + rename, like checkpoints)."""
        store.atomic_write_json(self.to_dict(), path)
        return path

    @classmethod
    def from_dict(cls, d: Dict) -> "FrontierIndex":
        schema = d.get("index_schema_version")
        if schema != INDEX_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported frontier-index schema version {schema!r}")
        version = d.get("sim_model_version")
        if version != costmodel.SIM_MODEL_VERSION:
            raise ValueError(
                f"frontier index was built under cost-model version "
                f"{version!r} but this build is "
                f"{costmodel.SIM_MODEL_VERSION}; serving its frontiers "
                "would answer queries with a cost model this build cannot "
                "reproduce.  Rebuild the index from a current-model "
                "campaign checkpoint (launch/serve.py --mode build-index)")
        entries = [IndexEntry(
            arch=ed["arch"], shape=ed["shape"],
            family=np.asarray(ed["family"], np.float64),
            workload=workload_from_dict(ed["workload"]),
            candidates=tuple(candidate_from_dict(c)
                             for c in ed["candidates"]),
            energy_j=np.asarray(ed["energy_j"], np.float64),
            latency_s=np.asarray(ed["latency_s"], np.float64),
            indices=np.asarray(ed["indices"], np.int64),
            feasible_count=int(ed["feasible_count"]),
        ) for ed in d["entries"]]
        return cls(entries, d["space"], d["constraint"], d["sim"],
                   d["evaluator"])

    @classmethod
    def load(cls, path: str) -> "FrontierIndex":
        """Load a saved index; refuses schema or cost-model version
        mismatches with an explicit rebuild hint."""
        with open(path) as f:
            return cls.from_dict(json.load(f))
