"""im2col-free conv2d Pallas TPU kernel — the paper's own CNN hot spot.

TPU adaptation of the CNN-inference workload: instead of a CUDA im2col +
GEMM, each (batch, out-row-tile) grid cell accumulates kh*kw MXU matmuls of
shape [tile_h*W_out, Cin] x [Cin, Cout] — the shifted-window decomposition.
Spatial shifts are STATIC python offsets, so every matmul maps straight onto
the systolic array with no gather.  Inputs are pre-padded by ops.py; VALID
semantics inside the kernel; stride 1 (ResNet 3x3 convs; strided 1x1 convs
lower to XLA directly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, tile_h: int,
                 w_out: int, cin: int, cout: int):
    t = pl.program_id(1)
    # halo read: rows [t*tile_h, t*tile_h + tile_h + kh - 1)
    x = x_ref[0, pl.ds(t * tile_h, tile_h + kh - 1)].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # [kh, kw, Cin, Cout]
    acc = jnp.zeros((tile_h * w_out, cout), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            win = x[i: i + tile_h, j: j + w_out, :]          # static slice
            acc = acc + jax.lax.dot_general(
                win.reshape(tile_h * w_out, cin), w[i, j],
                (((1,), (0,)), ((), ())))
    o_ref[0] = acc.reshape(tile_h, w_out, cout).astype(o_ref.dtype)


def conv2d_pallas(x, w, *, tile_h: int = 8, interpret: bool = True):
    """x: [B, H_in, W_in, Cin] (pre-padded); w: [kh, kw, Cin, Cout].

    VALID convolution, stride 1.  Returns [B, H_out, W_out, Cout].
    """
    B, H_in, W_in, cin = x.shape
    kh, kw, _, cout = w.shape
    H_out, W_out = H_in - kh + 1, W_in - kw + 1
    tile_h = min(tile_h, H_out)
    assert H_out % tile_h == 0, f"H_out {H_out} % tile_h {tile_h}"
    n_tiles = H_out // tile_h

    kernel = functools.partial(_conv_kernel, kh=kh, kw=kw, tile_h=tile_h,
                               w_out=W_out, cin=cin, cout=cout)
    return pl.pallas_call(
        kernel,
        grid=(B, n_tiles),
        in_specs=[
            # full-H block per batch: halo rows are pl.ds-sliced in-kernel
            pl.BlockSpec((1, H_in, W_in, cin), lambda b, t: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda b, t: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_h, W_out, cout), lambda b, t: (b, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H_out, W_out, cout), x.dtype),
        interpret=interpret,
    )(x, w)
