"""Fused DSE-sweep Pallas kernel: the campaign evaluator as ONE launch.

The streaming campaign's hot loop is not a neural-net op — it is the cost
model itself, evaluated over millions of (workload x candidate) pairs.  This
kernel moves the whole per-tile pipeline on device: census scaling
(``costmodel.scale_census``), the topology-aware roofline simulation
(``costmodel.simulate_batch`` with ``xp=jnp`` — literally the same function
the numpy oracle path runs, so the arithmetic cannot diverge), and the
constraint mask (``costmodel.sweep_feasibility``), for every cached workload
in one ``pallas_call``.

Layout: candidates arrive as one packed [len(CAND_COLS), N] column matrix
(lane-padded to 128, padding lanes carry ``valid=0``); per-workload scalars
as the packed [W, len(WL_COLS)] matrix, broadcast as a leading data axis
([W, 1] x [1, N] -> [W, N]) so the kernel body is W-independent — all
elementwise VPU math, no gathers, no host round-trips between workloads.

Precision tiers: in interpret mode (CPU CI / debugging) the whole sweep runs
float64 under a scoped ``jax.experimental.enable_x64`` so the resulting
frontier holds the float64 numpy evaluator's exact candidate set (values
agree to ~1 ulp — XLA fusion noise only); compiled on an accelerator it
runs float32 (the same tier as ``simulate_batch_jit``, ~1e-6 relative).

The jitted wrapper fuses the per-tile skyline pre-reduction
(``costmodel._screen_rows`` — a conservative dominance screen whose
survivors are a guaranteed superset of the tile's feasible Pareto set)
behind the kernel, so the frontier merge only ever handles O(survivors)
per tile — the same ``SweepReduced`` contract as the jit reference path
``costmodel.sweep_workloads_reduced_jit``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import costmodel

# packed candidate-column order of the [len(CAND_COLS), N] matrix the kernel
# consumes: batch axes first, then the gathered chip-table columns
CAND_COLS = ("n_chips", "freq_mhz", "mesh_pod", "mesh_data", "mesh_model",
             "valid") + costmodel.SWEEP_GATHER_FIELDS

LANE = 128   # TPU lane width; candidate tiles are padded to a multiple


def _sweep_kernel(wl_ref, cand_ref, e_ref, l_ref, f_ref, *,
                  sim: costmodel.SimConfig, max_power_w, max_latency_s,
                  min_hbm_fit: bool):
    """All workloads x the whole candidate tile in one kernel body.

    The workload axis is a broadcast DATA axis — per-workload scalars enter
    as [W, 1] column slices against the [1, N] candidate rows, so every
    simulation step is a single [W, N] elementwise op and the traced graph
    is independent of the workload count (no per-workload unrolling)."""
    col = {name: cand_ref[i:i + 1, :] for i, name in enumerate(CAND_COLS)}
    wl = {name: wl_ref[:, i:i + 1] for i, name in enumerate(costmodel.WL_COLS)}
    ana = costmodel.scale_census(wl, wl["base_chips"], col["n_chips"], xp=jnp)
    batch = costmodel.simulate_batch(
        ana, None, col["n_chips"], col["freq_mhz"], sim=sim, xp=jnp,
        gathered={f: col[f] for f in costmodel.SIM_GATHER_FIELDS},
        mesh_pod=col["mesh_pod"], mesh_data=col["mesh_data"],
        mesh_model=col["mesh_model"])
    feas = costmodel.sweep_feasibility(
        batch.power_w, batch.latency_s, col["n_chips"], col["hbm_bytes"],
        wl["base_chips"], wl["state_gb_per_device"], col["valid"],
        max_power_w, max_latency_s, min_hbm_fit, xp=jnp)
    e_ref[...] = jnp.broadcast_to(batch.energy_j, e_ref.shape)
    l_ref[...] = jnp.broadcast_to(batch.latency_s, l_ref.shape)
    f_ref[...] = feas.astype(e_ref.dtype)


def dse_sweep_pallas(cand_cols, wl_cols, *, sim: costmodel.SimConfig,
                     max_power_w=None, max_latency_s=None,
                     min_hbm_fit: bool = True, interpret: bool = True):
    """Raw kernel launch: (energy, latency, feasible) as [W, N] arrays.

    ``cand_cols`` is the packed [len(CAND_COLS), N] candidate matrix with N a
    multiple of ``LANE``; ``wl_cols`` the [W, len(WL_COLS)] workload matrix.
    """
    ncol, n = cand_cols.shape
    if ncol != len(CAND_COLS):
        raise ValueError(f"cand_cols must be [{len(CAND_COLS)}, N] "
                         f"({CAND_COLS}), got {cand_cols.shape}")
    w_count = wl_cols.shape[0]
    kernel = functools.partial(
        _sweep_kernel, sim=sim, max_power_w=max_power_w,
        max_latency_s=max_latency_s, min_hbm_fit=min_hbm_fit)
    dt = cand_cols.dtype
    return pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((w_count, n), dt)] * 3,
        interpret=interpret,
    )(wl_cols, cand_cols)


@functools.lru_cache(maxsize=None)
def _jit_dse_sweep(sim: costmodel.SimConfig, max_power_w, max_latency_s,
                   min_hbm_fit: bool, interpret: bool):
    def run(cand_cols, wl_cols):
        e, l, f = dse_sweep_pallas(
            cand_cols, wl_cols, sim=sim, max_power_w=max_power_w,
            max_latency_s=max_latency_s, min_hbm_fit=min_hbm_fit,
            interpret=interpret)
        feas = f > 0
        return costmodel._screen_rows(e, l, feas) + (e, l, feas)

    return jax.jit(run)


def pack_cand_cols(arrays: dict, dtype=np.float64) -> np.ndarray:
    """Stack the ``CAND_COLS`` entries of ``arrays`` into the packed matrix."""
    return np.stack([np.asarray(arrays[k], dtype) for k in CAND_COLS])


def _pad_lanes(cand_cols: np.ndarray, n_valid: int) -> np.ndarray:
    """Right-pad the lane axis to a ``LANE`` multiple; padding lanes copy
    lane 0 (safe arithmetic — no zero divides) with ``valid`` forced to 0."""
    n = cand_cols.shape[1]
    target = -(-max(n, 1) // LANE) * LANE
    if n < target:
        fill = np.repeat(cand_cols[:, :1], target - n, axis=1)
        cand_cols = np.concatenate([cand_cols, fill], axis=1)
    if n_valid < cand_cols.shape[1]:
        valid_row = CAND_COLS.index("valid")
        cand_cols = cand_cols.copy()
        cand_cols[valid_row, n_valid:] = 0.0
    return cand_cols


def dse_sweep_reduced(cand_cols: np.ndarray, wl_cols: np.ndarray, *,
                      sim: costmodel.SimConfig = costmodel.SimConfig(),
                      max_power_w: Optional[float] = None,
                      max_latency_s: Optional[float] = None,
                      min_hbm_fit: bool = True,
                      max_survivors: int = 2048,
                      n_valid: Optional[int] = None,
                      interpret: bool = True) -> costmodel.SweepReduced:
    """Fused sweep + on-device skyline reduction of one candidate tile.

    ``cand_cols`` [len(CAND_COLS), N] / ``wl_cols`` [W, len(WL_COLS)] as
    float64 numpy; ``n_valid`` marks the real (un-padded) tile length.
    Returns the ``SweepReduced`` contract shared with the jit reference
    path.  Interpret mode computes in float64 (scoped x64): the campaign
    frontier it produces holds the numpy evaluator's exact candidate set,
    with values agreeing to ~1 ulp (XLA fusion noise only).  Compiled mode
    computes in float32.
    """
    n = cand_cols.shape[1]
    n_valid = n if n_valid is None else int(n_valid)
    cand_cols = _pad_lanes(np.asarray(cand_cols, np.float64), n_valid)
    wl_cols = np.asarray(wl_cols, np.float64)
    fn = _jit_dse_sweep(sim, max_power_w, max_latency_s, bool(min_hbm_fit),
                        bool(interpret))
    if interpret:
        import jax.experimental
        with jax.experimental.enable_x64():
            out = fn(cand_cols, wl_cols)
    else:
        out = fn(cand_cols.astype(np.float32), wl_cols.astype(np.float32))
    return costmodel.build_sweep_reduced(out, int(max_survivors))
