"""Fused flash-attention Pallas TPU kernel.

Grid: (batch*heads, q_blocks, kv_blocks) — kv innermost ("arbitrary"
semantics, sequential per core) so the online-softmax running stats live in
VMEM scratch across kv steps and the fp32 score block NEVER round-trips to
HBM (the XLA fallback materializes it; see EXPERIMENTS.md §Perf for the
quantified delta).  Block shapes default to 128x128 — MXU-tile aligned.

Causal handling: blocks strictly above the diagonal are skipped via
``pl.when`` (no MXU work issued); the diagonal block applies an iota mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _body():
        q = q_ref[0].astype(jnp.float32)                   # [bq, hd]
        k = k_ref[0].astype(jnp.float32)                   # [bk, hd]
        v = v_ref[0].astype(jnp.float32)                   # [bk, hv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                            (block_q, block_k), 0)
            k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                            (block_q, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + \
            jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())))

    if causal:
        # skip blocks strictly above the diagonal: no MXU work issued
        pl.when((kj * block_k) < ((qi + 1) * block_q))(_body)
    else:
        _body()

    @pl.when(kj == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q, k, v: [BH, S, hd] (batch*heads folded).  Returns [BH, S, hv]."""
    BH, S, hd = q.shape
    hv = v.shape[-1]
    if scale is None:
        scale = hd ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    n_q, n_k = S // block_q, S // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),           # running max
            pltpu.VMEM((block_q,), jnp.float32),           # running sum
            pltpu.VMEM((block_q, hv), jnp.float32),        # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
