"""Jit'd public wrappers around the Pallas kernels.

These are the entry points models/benchmarks/campaigns use; each handles
layout (GQA head expansion, padding, column packing) and dispatches to the
kernel.  ``interpret`` defaults to auto-detection from the active JAX
backend (``default_interpret``): compiled on TPU, interpreted everywhere
else, overridable per call (``interpret=`` kwarg) or per process
(``REPRO_PALLAS_INTERPRET=0/1``).  Resolution happens BEFORE the jit
boundary so the env override is honored even across cached traces.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import costmodel
from repro.kernels.conv2d import conv2d_pallas
from repro.kernels.dse_sweep import (CAND_COLS, dse_sweep_reduced,
                                     pack_cand_cols)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode by default.

    Auto-detects from ``jax.default_backend()`` — compiled kernels on TPU,
    interpret mode on CPU/GPU backends (this container is CPU-only, so CI
    exercises interpret mode end to end).  The ``REPRO_PALLAS_INTERPRET``
    env var overrides the detection; an explicit ``interpret=`` kwarg on any
    wrapper overrides both.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off")
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _flash_attention(q, k, v, *, causal: bool, block_q: int, block_k: int,
                     interpret: bool):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    hv = v.shape[-1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hv)
    o = flash_attention_pallas(qf, kf, vf, causal=causal,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return o.reshape(B, H, S, hv).transpose(0, 2, 1, 3)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q: [B, S, H, hd]; k, v: [B, S, KV, hd] (GQA expanded here)."""
    return _flash_attention(q, k, v, causal=causal, block_q=block_q,
                            block_k=block_k,
                            interpret=_resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_scan(x, dt, A, B, C, *, chunk: int, interpret: bool):
    return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk, interpret=interpret)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """Mamba2 SSD scan: x [b,S,nh,hp], dt [b,S,nh], A [nh], B/C [b,S,1,ds]."""
    return _ssd_scan(x, dt, A, B, C, chunk=chunk,
                     interpret=_resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("stride", "padding", "tile_h",
                                             "interpret"))
def _conv2d(x, w, *, stride: int, padding: str, tile_h: int, interpret: bool):
    kh, kw = w.shape[:2]
    if stride != 1:
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if padding == "SAME" and (kh > 1 or kw > 1):
        x = jnp.pad(x, ((0, 0), (kh // 2, (kh - 1) // 2),
                        (kw // 2, (kw - 1) // 2), (0, 0)))
    return conv2d_pallas(x, w, tile_h=tile_h, interpret=interpret)


def conv2d(x, w, *, stride: int = 1, padding: str = "SAME", tile_h: int = 8,
           interpret: Optional[bool] = None):
    """NHWC conv via the Pallas kernel (stride-1 path); strided convs fall
    back to XLA (they are 1x1 projections in ResNet, already MXU-shaped)."""
    return _conv2d(x, w, stride=stride, padding=padding, tile_h=tile_h,
                   interpret=_resolve_interpret(interpret))


def dse_sweep(cand_cols, wl_cols, *,
              sim: costmodel.SimConfig = costmodel.SimConfig(),
              constraint=None, max_survivors: int = 2048,
              n_valid: Optional[int] = None,
              interpret: Optional[bool] = None) -> costmodel.SweepReduced:
    """Fused on-device campaign evaluator (see ``kernels.dse_sweep``).

    One launch evaluates all workload rows of ``wl_cols`` against the packed
    candidate tile ``cand_cols`` and reduces each to its feasible Pareto
    survivors + frontier-accounting aggregates.  ``constraint`` duck-types
    ``dse.Constraint`` (``max_power_w`` / ``max_latency_s`` /
    ``min_hbm_fit``); interpret mode (the CPU default) computes float64 —
    campaign frontiers then hold the numpy evaluator's exact candidate set
    — and compiled mode computes float32.
    """
    kw = dict(max_power_w=None, max_latency_s=None, min_hbm_fit=True)
    if constraint is not None:
        kw = dict(max_power_w=constraint.max_power_w,
                  max_latency_s=constraint.max_latency_s,
                  min_hbm_fit=constraint.min_hbm_fit)
    return dse_sweep_reduced(cand_cols, wl_cols, sim=sim,
                             max_survivors=max_survivors, n_valid=n_valid,
                             interpret=_resolve_interpret(interpret), **kw)
