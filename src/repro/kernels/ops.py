"""Jit'd public wrappers around the Pallas kernels.

These are the entry points models/benchmarks use; each handles layout
(GQA head expansion, padding) and dispatches to the kernel.  ``interpret``
defaults to True because this container is CPU-only; on real TPU the same
call sites pass interpret=False.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv2d import conv2d_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: [B, S, H, hd]; k, v: [B, S, KV, hd] (GQA expanded here)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    hv = v.shape[-1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hv)
    o = flash_attention_pallas(qf, kf, vf, causal=causal,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return o.reshape(B, H, S, hv).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """Mamba2 SSD scan: x [b,S,nh,hp], dt [b,S,nh], A [nh], B/C [b,S,1,ds]."""
    return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "tile_h",
                                             "interpret"))
def conv2d(x, w, *, stride: int = 1, padding: str = "SAME", tile_h: int = 8,
           interpret: bool = True):
    """NHWC conv via the Pallas kernel (stride-1 path); strided convs fall
    back to XLA (they are 1x1 projections in ResNet, already MXU-shaped)."""
    kh, kw = w.shape[:2]
    if stride != 1:
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if padding == "SAME" and (kh > 1 or kw > 1):
        x = jnp.pad(x, ((0, 0), (kh // 2, (kh - 1) // 2),
                        (kw // 2, (kw - 1) // 2), (0, 0)))
    return conv2d_pallas(x, w, tile_h=tile_h, interpret=interpret)
