"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q,k,v: [B, S, H, hd] (same H — GQA expansion happens in ops.py)."""
    B, S, H, hd = q.shape
    if scale is None:
        scale = hd ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_ref(x, dt, A, B, C):
    """Sequential (non-chunked) SSD recurrence — the exact oracle.

    x: [b, S, nh, hp]; dt: [b, S, nh]; A: [nh]; B, C: [b, S, ng, ds].
    Returns y: [b, S, nh, hp] fp32.
    """
    b, S, nh, hp = x.shape
    ng, ds = B.shape[-2], B.shape[-1]
    rep = nh // ng
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)     # [b,S,nh,ds]
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                               # [b,nh,hp],[b,nh],[b,nh,ds]x2
        decay = jnp.exp(dtt * A)                            # [b,nh]
        state = state * decay[..., None, None] + \
            jnp.einsum("bhs,bhp->bhps", Bt * dtt[..., None], xt)
        y = jnp.einsum("bhs,bhps->bhp", Ct, state)
        return state, y

    state0 = jnp.zeros((b, nh, hp, ds), jnp.float32)
    _, ys = jax.lax.scan(step, state0,
                         (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
                          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3)


def conv2d_ref(x, w, *, stride: int = 1):
    """x: [B, H, W, Cin] (already padded); w: [kh, kw, Cin, Cout]; VALID."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
