"""Fused Mamba2 SSD chunk-scan Pallas TPU kernel.

Grid: (batch, heads, chunks) — chunks innermost/sequential; the recurrent
state [hp, ds] lives in VMEM scratch across chunk steps.  Per chunk the
kernel computes the decay matrix L (segment sums), the dual masked matmul
(C B^T ⊙ L) @ (x·dt), the cross-chunk state contribution, and the state
update — none of the fp32 [Q,Q] intermediates ever reach HBM (the XLA
fallback materializes them per chunk).

Assumes ngroups == 1 (the assigned mamba2/zamba2 configs): B/C are indexed
per (batch, chunk) and shared across heads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # [Q, hp]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # [Q]
    A = a_ref[0]                                 # scalar (negative)
    B = b_ref[0, 0].astype(jnp.float32)          # [Q, ds]
    C = c_ref[0, 0].astype(jnp.float32)          # [Q, ds]

    dA = dt * A                                  # [Q]
    cum = jnp.cumsum(dA)                         # [Q]
    # decay matrix L[i,j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    xdt = x * dt[:, None]                        # [Q, hp]
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # [Q, Q]
    y_diag = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())))

    state = state_scr[...]                       # [hp, ds]
    decay_in = jnp.exp(cum)                      # [Q]
    y_off = jax.lax.dot_general(C * decay_in[:, None], state,
                                (((1,), (1,)), ((), ())))      # [Q, hp]
    y_ref[0, 0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: state' = state * exp(sum dA) + sum_q decay_end_q * dt_q x_q B_q^T
    decay_end = jnp.exp(cum[-1] - cum)           # [Q]
    contrib = jax.lax.dot_general(xdt * decay_end[:, None], B,
                                  (((0,), (0,)), ((), ())))    # [hp, ds]
    state_scr[...] = state * jnp.exp(cum[-1]) + contrib


def ssd_scan_pallas(x, dt, A, B, C, *, chunk: int = 128,
                    interpret: bool = True):
    """x: [b, S, nh, hp]; dt: [b, S, nh]; A: [nh]; B, C: [b, S, 1, ds].

    Returns y: [b, S, nh, hp] (x.dtype).
    """
    b, S, nh, hp = x.shape
    ds = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"{S} % {Q}"
    nc = S // Q
    # layouts: x -> [b, nh, nc, Q, hp]; dt -> [b, nh, nc, Q]; B/C -> [b, nc, Q, ds]
    xr = x.transpose(0, 2, 1, 3).reshape(b, nh, nc, Q, hp)
    dtr = dt.transpose(0, 2, 1).reshape(b, nh, nc, Q)
    Br = B[:, :, 0].reshape(b, nc, Q, ds)
    Cr = C[:, :, 0].reshape(b, nc, Q, ds)

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y = pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, hp), lambda bi, h, c: (bi, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda bi, h, c: (bi, h, c, 0)),
            pl.BlockSpec((1,), lambda bi, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, ds), lambda bi, h, c: (bi, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda bi, h, c: (bi, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, hp), lambda bi, h, c: (bi, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh, nc, Q, hp), x.dtype),
        scratch_shapes=[pltpu.VMEM((hp, ds), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, A.astype(jnp.float32), Br, Cr)
    return y.reshape(b, nh, S, hp).transpose(0, 2, 1, 3)
