"""Deterministic sharded synthetic data pipeline.

Production shape without production data: fixed-seed, restart-reproducible
(state = (seed, step) only — restoring a checkpoint replays the exact batch
sequence), host-sharded (each data-parallel host generates only its shard),
with background prefetch.  Token streams are Zipf-distributed so softmax /
router statistics look like language rather than uniform noise.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.3
    prefetch: int = 2
    host_index: int = 0
    host_count: int = 1


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    # independent stream per (seed, step, host): restart-safe, host-disjoint
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_index]))


def synth_batch(arch: ArchConfig, shape: ShapeConfig, cfg: DataConfig,
                step: int) -> Dict[str, np.ndarray]:
    rng = _batch_rng(cfg, step)
    local_batch = shape.global_batch // cfg.host_count
    if arch.family == "cnn":
        r = arch.image_size
        return {"images": rng.normal(size=(local_batch, r, r, 3)).astype(np.float32),
                "labels": rng.integers(0, arch.vocab_size, local_batch).astype(np.int32)}
    text = shape.seq_len - (arch.num_patches if arch.family == "vlm" else 0)
    toks = rng.zipf(cfg.zipf_a, size=(local_batch, text + 1)) % arch.vocab_size
    batch = {"tokens": toks[:, :-1].astype(np.int32),
             "labels": toks[:, 1:].astype(np.int32)}
    if arch.family == "vlm":
        batch["prefix_embeds"] = rng.normal(
            size=(local_batch, arch.num_patches, arch.d_model)).astype(np.float32) * 0.02
    if arch.family == "audio":
        batch["frames"] = rng.normal(
            size=(local_batch, arch.num_frames, arch.d_model)).astype(np.float32) * 0.02
    return batch


class DataIterator:
    """Background-prefetching iterator with an explicit, checkpointable cursor."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 cfg: Optional[DataConfig] = None, start_step: int = 0):
        self.arch, self.shape = arch, shape
        self.cfg = cfg or DataConfig()
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        s = self.step
        while not self._stop.is_set():
            batch = synth_batch(self.arch, self.shape, self.cfg, s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        s, batch = self._q.get()
        self.step = s + 1
        return batch

    def state(self) -> Dict:
        return {"seed": self.cfg.seed, "step": self.step}

    def close(self):
        self._stop.set()
